"""Prefix-cache benchmark: multi-turn conversations, cache on vs off.

Drives the real NeoEngine CLOSED-LOOP over the shared-system-prompt
``multiturn`` trace — a conversation's next turn is submitted only after the
previous turn finishes (the user "reads the answer"), which is where prefix
caching pays: every turn re-submits the whole history and the cache serves
the already-decoded prefix from tree pages instead of re-prefilling it.

Reported per config:

* ``prefill_tok``  — prefill tokens actually computed (suffix only on hits);
  the cache must cut this >= 2x on the multiturn trace.
* ``tok/s``        — end-to-end token throughput of the timed section.
* hit/promotion/demotion/eviction counters from :class:`PrefixCacheStats`.

Cache-off results are the compat baseline: greedy outputs are checked
identical between the two runs (the cache must change WHAT is computed, not
what is produced).
"""

from __future__ import annotations

import argparse
import time
from collections import defaultdict

import jax

from benchmarks.common import print_table, save_json
from repro.config import EngineConfig
from repro.configs import get_smoke_config
from repro.core.engine import NeoEngine
from repro.serving.traces import multiturn_trace


def build_conversations(n: int, turns: int, seed: int, vocab: int):
    """[(turn_order_key, prompt, output_len)] grouped by conversation."""
    trace = multiturn_trace(
        n, rate=1e9, seed=seed, turns=turns, vocab=min(vocab, 500),
        # prefill-heavy shape: long shared histories, short answers (the
        # agent/chat regime the prefix cache targets)
        system_len=384, context_len=96, user_len_median=64,
        output_median=10, max_output=16,
    )
    convs = defaultdict(list)
    for t in trace:
        convs[t.conv].append(t)
    for c in convs.values():
        # turn order within a conversation = prompt-length order (each turn
        # strictly extends the previous one)
        c.sort(key=lambda t: t.prompt_len)
    return list(convs.values())


def drive(eng: NeoEngine, conversations):
    """Closed-loop driver: round-robin over conversations; a conversation's
    next turn goes in only after its previous turn finished (different
    conversations still batch together).  Returns (outputs, total_tokens)."""
    outputs = {}
    total_tokens = 0
    cursors = [0] * len(conversations)
    pending = {}  # rid -> conv index
    while True:
        busy = set(pending.values())
        for ci, conv in enumerate(conversations):
            if cursors[ci] < len(conv) and ci not in busy:
                t = conv[cursors[ci]]
                rid = eng.submit(t.prompt, t.output_len)
                pending[rid] = ci
                busy.add(ci)
                cursors[ci] += 1
                total_tokens += t.prompt_len + t.output_len
        if not pending:
            break
        eng.step(now=eng.clock + 1e-3)
        for rid in list(pending):
            req = eng.requests[rid]
            if req.state.name in ("FINISHED", "ABORTED"):
                outputs[(pending.pop(rid), len(req.prompt))] = list(req.out_tokens)
    return outputs, total_tokens


def run(prefix_cache: bool, conversations, warmup, *, params, cfg,
        device_pages: int, host_pages: int, seed: int = 0,
        token_granular: bool = True):
    from repro.core.engine import EngineStats
    from repro.core.prefix_cache import PrefixCacheStats

    ecfg = EngineConfig(
        device_pool_pages=device_pages, host_pool_pages=host_pages,
        max_batch_tokens=2048, policy="neo", prefix_cache=prefix_cache,
        prefix_token_granular=token_granular,
        seed=seed,
    )
    eng = NeoEngine(cfg, ecfg, params=params)
    # warmup: same-shaped disjoint conversations compile every graph bucket
    # (incl. the suffix-prefill buckets) and settle the tree into steady
    # state, so the timed section measures sustained serving throughput
    drive(eng, warmup)
    eng.stats = EngineStats()
    if eng.prefix_cache is not None:
        eng.prefix_cache.stats = PrefixCacheStats()

    t0 = time.perf_counter()
    outputs, total_tokens = drive(eng, conversations)
    wall = time.perf_counter() - t0
    stats = eng.prefix_cache.stats if eng.prefix_cache else None
    res = {
        "prefix_cache": prefix_cache,
        "prefill_tok": eng.stats.prefill_tokens,
        "token_throughput": round(total_tokens / wall, 1),
        "wall_s": round(wall, 2),
        "iterations": eng.stats.iterations,
        "hit_rate": round(stats.hit_rate, 3) if stats else 0.0,
        "hit_tokens": stats.hit_tokens if stats else 0,
        "promoted": stats.promoted_pages if stats else 0,
        "demoted": stats.demoted_pages if stats else 0,
        "evicted": stats.evicted_pages if stats else 0,
        "cow": stats.cow_copies if stats else 0,
        # zero-copy host-tier serving
        "inplace_host_hits": stats.inplace_host_hits if stats else 0,
        "host_served_hit_tokens": stats.host_served_hit_tokens if stats else 0,
        "host_hit_pcie_bytes": stats.host_hit_pcie_bytes if stats else 0,
    }
    eng.close()
    return res, outputs


def run_host_serving(conversations, warmup, *, params, cfg,
                     host_pages: int) -> tuple:
    """``--host-serving`` section, two gates:

    1. **Zero-PCIe host serving** — a multiturn closed loop whose device
       pool is far too small for the conversation histories, so prefills
       land on the CPU queue and their host-resident prefixes must be
       served IN PLACE: ``inplace_host_hits > 0``, host-hit PCIe bytes and
       ``promoted_pages`` stay 0, greedy outputs bitwise identical to
       cache-off.
    2. **Token-granular vs page-aligned** — same trace (histories extend at
       arbitrary, non-page-aligned lengths), cache on in both modes: the
       token-granular radix must serve STRICTLY more hit tokens.

    Returns (rc, results-dict).
    """
    # a device pool smaller than one history forces the host tier to SERVE
    small_dev = 16
    common = dict(params=params, cfg=cfg, device_pages=small_dev,
                  host_pages=host_pages)
    rows, results = [], {}
    off, off_out = run(False, conversations, warmup, **common)
    on, on_out = run(True, conversations, warmup, **common)
    aligned, _ = run(True, conversations, warmup, token_granular=False,
                     **common)
    for key, r in (("hs_cache_off", off), ("hs_cache_on", on),
                   ("hs_page_aligned", aligned)):
        results[key] = r
        rows.append([key, r["prefill_tok"], r["hit_rate"], r["hit_tokens"],
                     r["inplace_host_hits"], r["host_served_hit_tokens"],
                     r["host_hit_pcie_bytes"], r["promoted"]])
    print("=== Host-tier serving (multiturn closed-loop, device pool "
          f"{small_dev} pages) ===")
    print_table(["config", "prefill tok", "hit rate", "hit tok", "inplace",
                 "host served", "hit PCIe B", "promo"], rows)

    rc = 0
    same = off_out == on_out
    results["hs_outputs_identical"] = same
    if not same:
        print("FAIL: host-served outputs differ from cache-off outputs")
        rc = 1
    if on["inplace_host_hits"] <= 0:
        print("FAIL: no in-place host-served prefix hits "
              "(inplace_host_hits == 0)")
        rc = 1
    if on["host_hit_pcie_bytes"] > 0 or on["promoted"] > 0:
        print(f"FAIL: host-resident prefix hits crossed PCIe "
              f"({on['host_hit_pcie_bytes']} B, promoted "
              f"{on['promoted']} pages)")
        rc = 1
    gain = on["hit_tokens"] - aligned["hit_tokens"]
    results["hs_token_granular_extra_hit_tokens"] = gain
    print(f"token-granular extra hit tokens vs page-aligned: {gain}")
    if gain <= 0:
        print("FAIL: token-granular matching did not increase hit tokens "
              "over page-aligned matching")
        rc = 1
    return rc, results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=12, help="total turns")
    ap.add_argument("--turns", type=int, default=4, help="turns/conversation")
    ap.add_argument("--device-pages", type=int, default=96)
    ap.add_argument("--host-pages", type=int, default=256)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--host-serving", action="store_true",
                    help="also run the zero-copy host-serving section: "
                         "in-place host hits with 0 promotion PCIe bytes + "
                         "token-granular vs page-aligned hit-token gate")
    args = ap.parse_args(argv)
    n = 8 if args.quick else args.n

    cfg = get_smoke_config("qwen3-0.6b")
    from repro.models.api import get_model

    params = get_model(cfg).init(jax.random.key(0))
    conversations = build_conversations(n, args.turns, seed=0, vocab=cfg.vocab_size)
    warmup = build_conversations(max(4, n // 2), args.turns, seed=7,
                                 vocab=cfg.vocab_size)

    rows, results = [], {}
    outs = {}
    for cache in (False, True):
        key = "cache_on" if cache else "cache_off"
        r, outs[cache] = run(cache, conversations, warmup, params=params,
                             cfg=cfg, device_pages=args.device_pages,
                             host_pages=args.host_pages)
        results[key] = r
        rows.append([key, r["prefill_tok"], r["token_throughput"], r["wall_s"],
                     r["hit_rate"], r["hit_tokens"], r["promoted"],
                     r["demoted"], r["evicted"], r["cow"]])
    print("=== Prefix cache (multiturn closed-loop, smoke qwen3-0.6b) ===")
    print_table(["config", "prefill tok", "tok/s", "wall s", "hit rate",
                 "hit tok", "promo", "demo", "evict", "cow"], rows)

    same = outs[False] == outs[True]
    reduction = results["cache_off"]["prefill_tok"] / max(
        1, results["cache_on"]["prefill_tok"])
    print(f"prefill-token reduction: {reduction:.2f}x; "
          f"outputs identical: {same}")
    results["prefill_reduction"] = round(reduction, 2)
    results["outputs_identical"] = same

    rc = 0
    if args.host_serving:
        rc, hs_results = run_host_serving(
            conversations, warmup, params=params, cfg=cfg,
            host_pages=args.host_pages)
        results.update(hs_results)

    save_json("prefix_cache.json", results)
    if not same:
        print("FAIL: cached outputs differ from cold outputs")
        return 1
    if reduction < 2.0:
        print("FAIL: prefill-token reduction < 2x on the multiturn trace")
        return 1
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
