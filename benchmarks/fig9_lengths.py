"""Fig. 9 — relative throughput over synthetic (input × output) length grids
for the three hardware classes, GPU-only as the 1.0 baseline.

Paper claims: peaks of ~+14% (H100-class), ~+26% (A10G), ~7.5× (T4); gains
rise to a balance point then decay toward (or slightly below) 1× as outputs
grow; NEO stays ≈1× when offloading cannot help.
"""

from __future__ import annotations

import argparse

from benchmarks.common import print_table, save_json
from repro.configs import get_config
from repro.serving.simulator import simulate
from repro.serving.traces import synthetic_trace

GRIDS = [
    ("T4+LLaMa-2-7B", "t4_g4dn", "llama2-7b", 1,
     [(400, o) for o in (10, 25, 50, 100, 200)]),
    ("A10G+LLaMa-3.1-8B", "a10g_g5_4x", "llama31-8b", 1,
     [(1000, o) for o in (25, 50, 100, 200, 400)]),
    ("2xH100+LLaMa-3.1-70B", "h100_sxm", "llama31-70b", 2,
     [(2000, o) for o in (25, 50, 100, 200, 400)]),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=120)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    results = {}
    for label, hw, arch, tp, grid in GRIDS:
        cfg = get_config(arch)
        rows = []
        best = 0.0
        if args.quick:
            grid = grid[::2]
        for li, lo in grid:
            # saturating arrival rate so throughput is capacity-bound
            trace = synthetic_trace(args.n, 50.0, li, lo, seed=0)
            base = simulate(cfg, trace, hw=hw, policy="gpu_only", tp=tp).throughput
            m = simulate(cfg, trace, hw=hw, policy="neo", tp=tp)
            rel = m.throughput / max(base, 1e-9)
            best = max(best, rel)
            rows.append([f"{li}x{lo}", round(base, 1), round(m.throughput, 1),
                         round(rel, 3), m.summary()["offload_frac"]])
        print(f"\n=== Fig9: {label} (GPU-only = 1.0) ===")
        print_table(["in x out", "gpu tok/s", "neo tok/s", "neo rel", "offl"], rows)
        print(f"peak gain: {(best - 1) * 100:+.1f}%")
        results[label] = {"rows": rows, "peak_rel": round(best, 3)}
    save_json("fig9_lengths.json", results)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
