"""Kernel micro-benchmarks (§4): wall-time of the jnp reference paths on this
host plus interpret-mode Pallas validation, and the structural VMEM/MXU
accounting of each Pallas kernel's BlockSpec tiling.

On-TPU wall times cannot be measured here; the structural table shows each
kernel's working set fits VMEM (16 MB/core) and its tiles are MXU-aligned.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, save_json


def timeit(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / iters


def bench_paged_decode():
    from repro.kernels.paged_decode.ops import paged_decode_attention

    rng = np.random.default_rng(0)
    B, H, KV, hd, page = 4, 8, 4, 64, 16
    P = 256
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, page, KV, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, page, KV, hd)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, P, size=(B, 16)), jnp.int32)
    lens = jnp.asarray(rng.integers(32, 256, size=(B,)), jnp.int32)
    t_ref = timeit(lambda *a: paged_decode_attention(*a, impl="ref"), q, kp, vp, bt, lens)
    # interpret-mode correctness delta
    o_ref = paged_decode_attention(q, kp, vp, bt, lens, impl="ref")
    o_pal = paged_decode_attention(q, kp, vp, bt, lens, impl="pallas", interpret=True)
    err = float(jnp.max(jnp.abs(o_ref - o_pal)))
    # structural: per-block VMEM = q tile + one kv page group
    vmem = (H * hd * 4) + 2 * (page * KV * hd * 4)
    return ["paged_decode", f"{t_ref * 1e3:.2f}ms", f"{err:.1e}", f"{vmem / 1e3:.0f}KB", "128-lane"]


def bench_flash_prefill():
    from repro.kernels.flash_prefill.ops import flash_prefill

    rng = np.random.default_rng(0)
    B, S, H, KV, hd = 1, 256, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    t_ref = timeit(lambda *a: flash_prefill(*a, impl="ref"), q, k, v)
    o_ref = flash_prefill(q, k, v, impl="ref")
    o_pal = flash_prefill(q, k, v, impl="pallas", interpret=True, blk_q=128, blk_k=128)
    err = float(jnp.max(jnp.abs(o_ref - o_pal)))
    vmem = (128 * hd * 4) * 2 + 2 * (128 * hd * 4)
    return ["flash_prefill", f"{t_ref * 1e3:.2f}ms", f"{err:.1e}", f"{vmem / 1e3:.0f}KB", "128x128 MXU"]


def bench_rwkv6():
    from repro.kernels.rwkv6_scan.ops import rwkv6_scan

    rng = np.random.default_rng(0)
    B, T, H, N = 1, 64, 4, 32
    r, k, v, w = (jnp.asarray(rng.normal(size=(B, T, H, N)), jnp.float32) for _ in range(4))
    w = jnp.exp(-jnp.exp(w))  # decay in (0,1)
    u = jnp.asarray(rng.normal(size=(H, N)), jnp.float32)
    s0 = jnp.zeros((B, H, N, N), jnp.float32)
    t_ref = timeit(lambda *a: rwkv6_scan(*a, impl="scan")[0], r, k, v, w, u, s0)
    y0, _ = rwkv6_scan(r, k, v, w, u, s0, impl="scan")
    y1, _ = rwkv6_scan(r, k, v, w, u, s0, impl="pallas", interpret=True)
    err = float(jnp.max(jnp.abs(y0 - y1)))
    vmem = 4 * (64 * N * 4) + N * N * 4
    return ["rwkv6_scan", f"{t_ref * 1e3:.2f}ms", f"{err:.1e}", f"{vmem / 1e3:.0f}KB", "chunked scan"]


def bench_mamba2():
    from repro.kernels.mamba2_ssd.ops import mamba2_ssd

    rng = np.random.default_rng(0)
    B, T, H, P_, N = 1, 64, 2, 32, 32
    x = jnp.asarray(rng.normal(size=(B, T, H, P_)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(B, T, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, T, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(B, T, N)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(H,)), jnp.float32)
    s0 = jnp.zeros((B, H, P_, N), jnp.float32)
    t_ref = timeit(lambda *a: mamba2_ssd(*a, impl="scan")[0], x, dt, A, Bm, C, D, s0)
    y0, _ = mamba2_ssd(x, dt, A, Bm, C, D, s0, impl="scan")
    y1, _ = mamba2_ssd(x, dt, A, Bm, C, D, s0, impl="pallas", interpret=True)
    err = float(jnp.max(jnp.abs(y0 - y1)))
    vmem = (64 * P_ * 4) * 2 + P_ * N * 4
    return ["mamba2_ssd", f"{t_ref * 1e3:.2f}ms", f"{err:.1e}", f"{vmem / 1e3:.0f}KB", "SSD blocks"]


def main(argv=None) -> int:
    rows = [bench_paged_decode(), bench_flash_prefill(), bench_rwkv6(), bench_mamba2()]
    print("=== Pallas kernels: ref wall-time (this host), interpret-mode max|Δ| vs oracle, VMEM/block ===")
    print_table(["kernel", "ref ms", "pallas max err", "VMEM/block", "tiling"], rows)
    save_json("kernels.json", {r[0]: r[1:] for r in rows})
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
