"""Bench-trend gate: run the engine + prefix-cache smokes, write the
schema'd ``BENCH_engine.json`` summary at the REPO ROOT, and fail on a
perf-trajectory regression vs the checked-in baseline.

This is the CI ``bench-trend`` job's entry point (the summary file is
uploaded as a build artifact, so the trajectory is inspectable per commit).
Schema (``neo-bench-trend/v6``; documented in ``benchmarks/README.md``):

* ``engine.*_tok_s``      — smoke token throughputs (RECORDED, not gated:
  they are wall-times of whatever machine ran the job);
* ``engine.bubble_fraction`` — measured pipeline bubble of the
  micro-batched fastdecode smoke (GATED: must not regress past the
  checked-in baseline + tolerance — the structural-overlap headline);
* ``engine.microbatched_steps`` / ``engine.borrowed_lane_steps`` — unified
  lane-plan counters (GATED > 0: the splits must actually fire);
* ``prefix_cache.hit_rate`` / ``prefill_reduction`` — multiturn cache
  smoke (hit_rate GATED against baseline - tolerance);
* ``prefix_cache.host_served_hit_tokens`` / ``inplace_host_hits`` —
  zero-copy host-tier serving counters from the ``--host-serving`` section
  (GATED > 0: host-resident prefixes must be served in place, and the
  section itself fails on any host-hit PCIe bytes);
* ``serving.*`` — sustained-load A/B (closed-loop lockstep vs open-loop
  continuous batching with plan-ahead): goodput and p99 TTFT/TPOT for both
  loops (RECORDED — wall-clock latencies are machine-dependent), plus
  ``planahead_hits`` (GATED > 0: speculative plans must actually be
  adopted) and ``bitwise_identical`` (GATED: plan-ahead may never change
  greedy outputs);
* ``obs.tracing_overhead`` — fractional tok/s cost of structured tracing
  on the decode-heavy fastdecode smoke (GATED <= TRACING_OVERHEAD_TOL:
  the tracer must stay out of the engine's way);
* ``obs.reconcile_ok`` — the span timeline reproduces EngineStats' lane
  busy / overlap / bubble / swap-hidden / plan-ahead accounting (GATED
  true: the trace is a standing audit of every other gated number);
* ``sharded.*`` (v5) — the tensor-parallel A/B (``engine_sharded.py``,
  TP=1 vs TP=2 on a fake-device CPU mesh): ``tp2_bitwise_ok`` (GATED:
  gather-TP may never change greedy outputs), ``swap_bytes_equal`` and
  ``stream_split_exact`` (GATED: per-shard copy streams must partition
  the TP=1 byte totals exactly), plus the recorded per-shard byte split;
* ``spec.*`` (v6) — the speculative-decoding A/B (``engine_real.py
  --spec-only``, low-concurrency fastdecode smoke): ``bitwise_ok`` (GATED:
  neither drafter may ever change greedy outputs), ``oracle_accepted``
  (GATED > 0: the verify pass must actually accept) and
  ``oracle_accept_len_hist`` (GATED: populated at length >= 1), plus
  ``oracle_speedup`` (GATED > 1: accepted tokens ride the verify pass
  instead of full engine iterations) and the recorded n-gram-drafter
  accept counters.

``--write-baseline`` refreshes ``benchmarks/BENCH_baseline.json`` (commit
the result deliberately — that is the trajectory being gated).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from benchmarks.common import FIG_DIR, HERE

SCHEMA = "neo-bench-trend/v6"
REPO_ROOT = os.path.dirname(HERE)
BASELINE_PATH = os.path.join(HERE, "BENCH_baseline.json")
SUMMARY_PATH = os.path.join(REPO_ROOT, "BENCH_engine.json")

# Gate tolerances: bubble_fraction is a structural ratio (stable across
# machines), throughputs are not — only ratios/counters are gated.
BUBBLE_TOL = 0.05
HIT_RATE_TOL = 0.05
TRACING_OVERHEAD_TOL = 0.05


def _load(name: str) -> dict:
    with open(os.path.join(FIG_DIR, name)) as f:
        return json.load(f)


def collect(n: int) -> tuple[int, dict]:
    """Run the smokes (micro-batch, mixed-lane, prefix-cache) and collate
    their figure JSONs into the trend summary.  Returns (rc, summary)."""
    from benchmarks import engine_real, engine_sharded, prefix_cache
    from repro.launch.serve import run_sustained

    rc = 0
    rc |= engine_real.main(["--microbatch-only", "--n", str(n)])
    rc |= engine_real.main(["--mixed-lane-only"])
    rc |= engine_real.main(["--obs-only", "--n", str(n)])
    rc |= engine_real.main(["--spec-only", "--n", str(n)])
    rc |= prefix_cache.main(["--quick", "--host-serving"])
    rc |= engine_sharded.main([])
    sus = run_sustained(n=max(n, 12), rate=8.0, seed=0)

    er = _load("engine_real.json")
    pc = _load("prefix_cache.json")
    sh = _load("engine_sharded.json")
    mb_on = er["fastdecode_mb_on"]
    mb_off = er["fastdecode_mb_off"]
    mixed = er["mixed_pipelined"]
    summary = {
        "schema": SCHEMA,
        "arch": "qwen3-0.6b (smoke)",
        "engine": {
            "fastdecode_mb_on_tok_s": mb_on["token_throughput"],
            "fastdecode_mb_off_tok_s": mb_off["token_throughput"],
            "mixed_pipelined_tok_s": mixed["token_throughput"],
            "bubble_fraction": mb_on["bubble_fraction"],
            "bubble_fraction_serialized": mb_off["bubble_fraction"],
            "microbatched_steps": mb_on["microbatched_steps"],
            "borrowed_lane_steps": mixed["borrowed_lane_steps"],
            "lane_count_steps": mixed["lane_count_steps"],
        },
        "prefix_cache": {
            "hit_rate": pc["cache_on"]["hit_rate"],
            "prefill_reduction": pc["prefill_reduction"],
            "cache_on_tok_s": pc["cache_on"]["token_throughput"],
            # zero-copy host-tier serving (--host-serving section)
            "host_served_hit_tokens": pc["hs_cache_on"]["host_served_hit_tokens"],
            "inplace_host_hits": pc["hs_cache_on"]["inplace_host_hits"],
            "token_granular_extra_hit_tokens":
                pc["hs_token_granular_extra_hit_tokens"],
        },
        "serving": {
            "closed_goodput_rps": sus["closed"]["goodput_rps"],
            "open_goodput_rps": sus["open"]["goodput_rps"],
            "closed_ttft_p99_ms": sus["closed"]["ttft_p99_ms"],
            "open_ttft_p99_ms": sus["open"]["ttft_p99_ms"],
            "closed_tpot_p99_ms": sus["closed"]["tpot_p99_ms"],
            "open_tpot_p99_ms": sus["open"]["tpot_p99_ms"],
            "planahead_hits": sus["open"]["planahead_hits"],
            "planahead_replans": sus["open"]["planahead_replans"],
            "planahead_hidden_s": sus["open"]["planahead_hidden_s"],
            "bitwise_identical": sus["gates"]["bitwise_identical"],
        },
        "obs": {
            "tracing_off_tok_s": er["obs_tracing_off"]["token_throughput"],
            "tracing_on_tok_s": er["obs_tracing_on"]["token_throughput"],
            "tracing_overhead": er["obs_tracing_on"]["tracing_overhead"],
            "reconcile_ok": er["obs_tracing_on"]["reconcile_ok"],
            "trace_events": er["obs_tracing_on"]["trace_events"],
            "trace_dropped": er["obs_tracing_on"]["trace_dropped"],
        },
        "spec": {
            "bitwise_ok": er["spec_gates"]["bitwise_ok"],
            "oracle_speedup": er["spec_gates"]["oracle_speedup"],
            "oracle_accepted": er["spec_oracle"]["accepted_tokens"],
            "oracle_accept_len_hist": er["spec_oracle"]["accept_len_hist"],
            "ngram_drafted": er["spec_ngram"]["drafted_tokens"],
            "ngram_accepted": er["spec_ngram"]["accepted_tokens"],
            "spec_off_tok_s": er["spec_off"]["token_throughput"],
            "spec_ngram_tok_s": er["spec_ngram"]["token_throughput"],
            "spec_oracle_tok_s": er["spec_oracle"]["token_throughput"],
        },
        "sharded": {
            "tp2_bitwise_ok": sh["tp2_bitwise_ok"],
            "swap_bytes_equal": sh["swap_bytes_equal"],
            "stream_split_exact": sh["stream_split_exact"],
            "bytes_out": sh["bytes_out"],
            "bytes_in": sh["bytes_in"],
            "tp2_copy_streams": sh["tp2_copy_streams"],
            "tp1_tok_s": sh["tp1_tok_s"],
            "tp2_tok_s": sh["tp2_tok_s"],
        },
    }
    return rc, summary


def gate(summary: dict, baseline: dict) -> int:
    """Compare the fresh summary against the checked-in baseline; returns
    the number of regressions (0 == green)."""
    fails = 0
    b_eng, s_eng = baseline["engine"], summary["engine"]
    if s_eng["bubble_fraction"] > b_eng["bubble_fraction"] + BUBBLE_TOL:
        print(f"[bench_trend] FAIL: bubble_fraction regressed "
              f"{b_eng['bubble_fraction']} -> {s_eng['bubble_fraction']} "
              f"(tol {BUBBLE_TOL})")
        fails += 1
    if s_eng["microbatched_steps"] == 0:
        print("[bench_trend] FAIL: no micro-batched steps in the fastdecode "
              "smoke")
        fails += 1
    if s_eng["borrowed_lane_steps"] == 0:
        print("[bench_trend] FAIL: no borrowed-lane steps in the mixed-plan "
              "smoke")
        fails += 1
    b_pc, s_pc = baseline["prefix_cache"], summary["prefix_cache"]
    if s_pc["hit_rate"] < b_pc["hit_rate"] - HIT_RATE_TOL:
        print(f"[bench_trend] FAIL: prefix-cache hit_rate regressed "
              f"{b_pc['hit_rate']} -> {s_pc['hit_rate']} (tol {HIT_RATE_TOL})")
        fails += 1
    if s_pc.get("host_served_hit_tokens", 0) <= 0:
        print("[bench_trend] FAIL: no host-served hit tokens in the "
              "host-serving smoke")
        fails += 1
    if s_pc.get("inplace_host_hits", 0) <= 0:
        print("[bench_trend] FAIL: no in-place host hits in the "
              "host-serving smoke")
        fails += 1
    s_srv = summary.get("serving", {})
    if s_srv.get("planahead_hits", 0) <= 0:
        print("[bench_trend] FAIL: plan-ahead never adopted a speculative "
              "plan in the sustained-load smoke")
        fails += 1
    if not s_srv.get("bitwise_identical", False):
        print("[bench_trend] FAIL: plan-ahead changed greedy outputs in the "
              "sustained-load smoke")
        fails += 1
    s_sh = summary.get("sharded", {})
    if not s_sh.get("tp2_bitwise_ok", False):
        print("[bench_trend] FAIL: TP=2 greedy outputs diverge from TP=1 "
              "in the sharded smoke")
        fails += 1
    if not s_sh.get("swap_bytes_equal", False):
        print("[bench_trend] FAIL: TP=2 swap byte totals differ from TP=1 "
              "in the sharded smoke")
        fails += 1
    if not s_sh.get("stream_split_exact", False):
        print("[bench_trend] FAIL: per-shard copy-stream bytes do not "
              "partition the totals in the sharded smoke")
        fails += 1
    s_sp = summary.get("spec", {})
    if not s_sp.get("bitwise_ok", False):
        print("[bench_trend] FAIL: speculative decoding changed greedy "
              "outputs in the spec smoke")
        fails += 1
    if s_sp.get("oracle_accepted", 0) <= 0:
        print("[bench_trend] FAIL: the verify pass accepted 0 drafted "
              "tokens in the spec smoke")
        fails += 1
    hist = s_sp.get("oracle_accept_len_hist", {})
    if not any(int(k) >= 1 and v > 0 for k, v in hist.items()):
        print("[bench_trend] FAIL: accepted-length histogram empty at >= 1 "
              "in the spec smoke")
        fails += 1
    if s_sp.get("oracle_speedup", 0.0) <= 1.0:
        print(f"[bench_trend] FAIL: no speculative throughput win "
              f"(oracle_speedup={s_sp.get('oracle_speedup')})")
        fails += 1
    s_obs = summary.get("obs", {})
    if s_obs.get("tracing_overhead", 0.0) > TRACING_OVERHEAD_TOL:
        print(f"[bench_trend] FAIL: tracing overhead "
              f"{s_obs['tracing_overhead']:.2%} exceeds "
              f"{TRACING_OVERHEAD_TOL:.0%} of untraced tok/s")
        fails += 1
    if not s_obs.get("reconcile_ok", False):
        print("[bench_trend] FAIL: span timeline disagrees with EngineStats "
              "(reconcile) in the tracing smoke")
        fails += 1
    if not fails:
        print(f"[bench_trend] OK: bubble {s_eng['bubble_fraction']} "
              f"(baseline {b_eng['bubble_fraction']}), hit_rate "
              f"{s_pc['hit_rate']} (baseline {b_pc['hit_rate']})")
    return fails


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=12,
                    help="requests per engine smoke run")
    ap.add_argument("--write-baseline", action="store_true",
                    help="refresh benchmarks/BENCH_baseline.json instead of "
                         "gating against it")
    ap.add_argument("--baseline", default=BASELINE_PATH)
    args = ap.parse_args(argv)

    rc, summary = collect(args.n)
    with open(SUMMARY_PATH, "w") as f:
        json.dump(summary, f, indent=1)
        f.write("\n")
    print(f"[bench_trend] wrote {SUMMARY_PATH}")
    if rc:
        print("[bench_trend] FAIL: a smoke gate failed (see above)")
        return rc
    if args.write_baseline:
        with open(args.baseline, "w") as f:
            json.dump(summary, f, indent=1)
            f.write("\n")
        print(f"[bench_trend] baseline refreshed: {args.baseline}")
        return 0
    if not os.path.exists(args.baseline):
        print(f"[bench_trend] FAIL: no baseline at {args.baseline} "
              f"(run with --write-baseline and commit it)")
        return 1
    with open(args.baseline) as f:
        baseline = json.load(f)
    return 1 if gate(summary, baseline) else 0


if __name__ == "__main__":
    sys.exit(main())
