"""Shared helpers for the paper-figure benchmarks.

Every benchmark prints a CSV-ish table AND writes JSON next to it under
``experiments/figures/``.  The simulator drives the REAL scheduler; stage
durations come from the calibrated hardware model (DESIGN.md §7).
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

import repro.configs.paper_models  # noqa: F401  (registers llama models)

HERE = os.path.dirname(os.path.abspath(__file__))
FIG_DIR = os.path.join(HERE, "..", "experiments", "figures")


def save_json(name: str, data) -> str:
    os.makedirs(FIG_DIR, exist_ok=True)
    path = os.path.join(FIG_DIR, name)
    with open(path, "w") as f:
        json.dump(data, f, indent=1)
    return path


def print_table(headers: List[str], rows: List[List]) -> None:
    w = [max(len(str(h)), max((len(str(r[i])) for r in rows), default=0))
         for i, h in enumerate(headers)]
    print(" | ".join(str(h).ljust(w[i]) for i, h in enumerate(headers)))
    print("-+-".join("-" * x for x in w))
    for r in rows:
        print(" | ".join(str(c).ljust(w[i]) for i, c in enumerate(r)))


# The paper's three Fig. 6 settings.
FIG6_SETTINGS = [
    # (label, hw, arch, trace, tp, rates)
    ("T4+LLaMa-2-7B+OSC", "t4_g4dn", "llama2-7b", "osc", 1,
     (0.25, 0.5, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 8.0)),
    ("A10G+LLaMa-3.1-8B+AC", "a10g_g5_4x", "llama31-8b", "ac", 1,
     (0.4, 0.8, 1.2, 1.6, 2.0, 2.4, 2.8, 3.2, 4.0, 4.8)),
    ("2xH100+LLaMa-3.1-70B+AC", "h100_sxm", "llama31-70b", "ac", 2,
     (0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5, 4.0, 5.0, 6.0)),
]
