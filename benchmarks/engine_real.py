"""Real-engine benchmark (Fig. 10b spirit): GPU token throughput of the
actual NeoEngine on this host, smoke-scale models, feeding a whole trace at
once (the paper's "feed the Azure Code trace all at once" methodology).

Compares NEO scheduling vs the GPU-only baseline ON REAL EXECUTION — the
numbers are host-CPU wall times (not TPU projections), so the meaningful
output is the RELATIVE behaviour and the scheduler decision mix.
"""

from __future__ import annotations

import argparse
import time
from typing import Optional, Tuple

import numpy as np

from benchmarks.common import print_table, save_json
from repro.config import EngineConfig
from repro.configs import get_smoke_config
from repro.core.engine import EngineStats, NeoEngine
from repro.core.transfer import TransferStats
from repro.models.api import get_model
from repro.serving.traces import get_trace


class _OracleDrafter:
    """Replay drafter for the speculative upper-bound run: proposes exactly
    the serial continuation recorded from the non-speculative reference, so
    every draft verifies and the chain emits its full K+1 tokens per step.
    Measures the machinery's ceiling independent of n-gram draft quality."""

    def __init__(self):
        self.table = {}

    def feed(self, prompt, out):
        seq = list(prompt) + list(out)
        for t in range(len(out)):
            self.table[tuple(seq[:len(prompt) + t])] = list(out[t:])

    def propose(self, tokens, k):
        return self.table.get(tuple(tokens), [])[:k]


def run(policy: str, n: int, seed: int = 0, pipeline: bool = True,
        microbatch: bool = True, tracing: bool = False, spec: bool = False,
        oracle_from: Optional[dict] = None):
    cfg = get_smoke_config("qwen3-0.6b")
    model = get_model(cfg)
    import jax

    params = model.init(jax.random.key(seed))
    ecfg = EngineConfig(
        device_pool_pages=24, host_pool_pages=128, max_batch_tokens=1024,
        policy=policy, pipeline=pipeline, microbatch=microbatch,
        tracing=tracing, spec_decode=spec or oracle_from is not None,
        seed=seed,
    )
    eng = NeoEngine(cfg, ecfg, params=params)
    oracle = None
    if oracle_from is not None:
        oracle = _OracleDrafter()
        eng.drafter = oracle
    rng = np.random.default_rng(seed)
    # Warmup: a burst big enough to trigger offload (device pool pressure),
    # exercising the prefill/decode/swap graph buckets so the timed section
    # measures steady-state serving throughput rather than XLA compile time
    # (the paper's figures report sustained serving).
    warm = get_trace("osc", 6, 1e9, seed + 1)
    for t in warm:
        t.prompt_len = 256
        t.output_len = 16
        t.materialise(rng, cfg.vocab_size)
        eng.submit(t.prompt, t.output_len)
    eng.run_until_done(max_iters=2000)

    trace = get_trace("osc", n, 1e9, seed)  # all at once
    for i, t in enumerate(trace):
        t.prompt_len = min(t.prompt_len, 256)
        # decode-heavy outputs (the paper's code/conv traces decode hundreds
        # of tokens per request — decode is where the asymmetric overlap acts)
        t.output_len = min(t.output_len, 64)
        t.materialise(rng, cfg.vocab_size)
        if oracle is not None:
            oracle.feed(t.prompt, oracle_from[i])
    if ecfg.spec_decode:
        # dress rehearsal: the batched verify pass lands pseudo-row batches
        # in bigger decode (D, MP) buckets than the burst warmup ever hits —
        # run the exact workload once untimed so every bucket the timed
        # section needs is already compiled (steady-state serving is what
        # the figures report)
        for t in trace:
            eng.submit(t.prompt, t.output_len)
        eng.run_until_done(max_iters=5000)

    eng.stats = EngineStats()
    if eng.pool is not None:
        eng.pool.swap_bytes = 0
    if eng.transfer is not None:
        eng.transfer.stats = TransferStats()
    if tracing:
        # fresh timeline after the stats reset, so the spans stay
        # reconcilable against the counters of the timed section alone
        from repro.obs.tracer import SpanTracer
        eng.attach_tracer(SpanTracer(ecfg.trace_buffer))

    total_tokens = 0
    rids = []
    for t in trace:
        rids.append(eng.submit(t.prompt, t.output_len))
        total_tokens += t.prompt_len + t.output_len
    t0 = time.perf_counter()
    eng.run_until_done(max_iters=5000)
    wall = time.perf_counter() - t0
    done = sum(1 for rid in rids if eng.requests[rid].state.name == "FINISHED")
    out = {
        "policy": policy,
        "pipeline": pipeline,
        "microbatch": microbatch,
        "requests_done": done,
        "token_throughput": round(total_tokens / wall, 1),
        "wall_s": round(wall, 2),
        "iterations": eng.stats.iterations,
        "offloaded": eng.stats.offloaded_decodes,
        "device": eng.stats.device_decodes,
        "swap_MB": round(eng.pool.swap_bytes / 1e6, 1) if eng.pool else 0,
        "modes": dict(eng.stats.mode_counts),
        "host_busy_s": round(eng.stats.host_busy_time, 2),
        "device_busy_s": round(eng.stats.device_busy_time, 2),
        "overlap_s": round(eng.stats.pipeline_overlap_time, 3),
        "bubble_fraction": round(eng.stats.bubble_fraction, 3),
        "swap_hidden_MB": round(eng.stats.swap_hidden_bytes / 1e6, 3),
        "microbatched_steps": eng.stats.microbatched_steps,
        "serial_b1_steps": eng.stats.serial_b1_steps,
        "borrowed_lane_steps": eng.stats.borrowed_lane_steps,
        "lane_count_steps": {str(k): v
                             for k, v in sorted(eng.stats.lane_counts.items())},
        "lane_busy_s": {k: round(v, 3)
                        for k, v in sorted(eng.stats.lane_busy_time.items())},
        "spec_steps": eng.stats.spec_steps,
        "drafted_tokens": eng.stats.drafted_tokens,
        "accepted_tokens": eng.stats.accepted_tokens,
        "rejected_drafts": eng.stats.rejected_drafts,
        "spec_busy_s": round(eng.stats.spec_busy_time, 3),
        "accept_len_hist": {str(k): v for k, v in
                            sorted(eng.stats.accept_len_hist.items())},
    }
    if tracing:
        from repro.obs.reconcile import reconcile
        rep = reconcile(eng.tracer, eng.stats)
        out["reconcile_ok"] = rep.ok
        out["reconcile_failed"] = rep.failed()
        out["trace_events"] = eng.tracer.total
        out["trace_dropped"] = eng.tracer.dropped
    outputs = {i: list(eng.requests[rid].out_tokens)
               for i, rid in enumerate(rids)}
    eng.close()
    return out, outputs


def run_microbatch_section(n: int, on: Optional[Tuple[dict, dict]] = None
                           ) -> Tuple[int, dict]:
    """Batch-1-only overlap: fastdecode(+) decode iterations have no device
    lane, so without micro-batching host attention runs fully serialized.
    Compares microbatch off vs on and GATES: greedy outputs must be bitwise
    identical and bubble_fraction must not regress (strictly improve, in
    practice) on the iterations that were eligible.

    ``on`` reuses the policy loop's fastdecode run (microbatch defaults on)
    so the full benchmark doesn't execute the same configuration twice;
    when absent, off runs first so warm compile caches don't bias against
    the serialized path (gate-conservative either way).
    """
    results = {}
    r_off, out_off = run("fastdecode", n, pipeline=True, microbatch=False)
    r_on, out_on = on if on is not None else run(
        "fastdecode", n, pipeline=True, microbatch=True)
    results["fastdecode_mb_off"] = r_off
    results["fastdecode_mb_on"] = r_on
    rows = [[k, r["microbatched_steps"], r["serial_b1_steps"],
             r["overlap_s"], r["bubble_fraction"], r["token_throughput"]]
            for k, r in results.items()]
    print("=== Micro-batched batch-1-only plans (fastdecode, smoke) ===")
    print_table(["run", "mb steps", "serial b1", "overlap s", "bubble",
                 "tok/s"], rows)
    rc = 0
    if out_on != out_off:
        print("[engine_real] FAIL: microbatch on/off greedy outputs diverge")
        rc = 1
    if r_on["microbatched_steps"] == 0:
        print("[engine_real] FAIL: no micro-batched steps on a fastdecode "
              "trace (batch-1-only plans must split)")
        rc = 1
    if r_on["bubble_fraction"] > r_off["bubble_fraction"]:
        print(f"[engine_real] FAIL: bubble_fraction regressed "
              f"({r_on['bubble_fraction']} > {r_off['bubble_fraction']})")
        rc = 1
    print(f"[engine_real] microbatch gate: bubble {r_off['bubble_fraction']}"
          f" -> {r_on['bubble_fraction']}, outputs "
          f"{'identical' if out_on == out_off else 'DIVERGED'}")
    return rc, results


def run_obs_section(n: int, off: Optional[Tuple[dict, dict]] = None
                    ) -> Tuple[int, dict]:
    """Tracing A/B: the decode-heavy fastdecode smoke untraced vs traced.
    GATES: greedy outputs bitwise identical, reconcile() (span timeline vs
    EngineStats) passes, and the ring never dropped an event at smoke
    scale.  The throughput delta is RECORDED as ``tracing_overhead`` —
    bench_trend gates it at <= 5% of the untraced tok/s.

    ``off`` reuses the micro-batch section's tracing-off fastdecode run so
    the A side isn't executed twice.
    """
    r_off, out_off = off if off is not None else run(
        "fastdecode", n, pipeline=True, microbatch=True)
    r_on, out_on = run("fastdecode", n, pipeline=True, microbatch=True,
                       tracing=True)

    def _overhead(a, b):
        return max(0.0, 1.0 - b["token_throughput"]
                   / max(a["token_throughput"], 1e-9))

    overhead = _overhead(r_off, r_on)
    if overhead > 0.05:
        # wall-clock A/B on a shared host is noisy: re-measure both sides
        # once and keep each side's best run (min-wall estimator) before
        # declaring the tracer itself slow
        r_off2, _ = run("fastdecode", n, pipeline=True, microbatch=True)
        r_on2, _ = run("fastdecode", n, pipeline=True, microbatch=True,
                       tracing=True)
        if r_off2["token_throughput"] > r_off["token_throughput"]:
            r_off = r_off2
        if r_on2["token_throughput"] > r_on["token_throughput"]:
            r_on = r_on2
        overhead = _overhead(r_off, r_on)
    r_on = dict(r_on)
    r_on["tracing_overhead"] = round(overhead, 4)
    results = {"obs_tracing_off": r_off, "obs_tracing_on": r_on}
    print("=== Structured tracing A/B (fastdecode, smoke) ===")
    print_table(["run", "tok/s", "bubble", "events", "dropped", "reconcile"],
                [["tracing off", r_off["token_throughput"],
                  r_off["bubble_fraction"], "-", "-", "-"],
                 ["tracing on", r_on["token_throughput"],
                  r_on["bubble_fraction"], r_on["trace_events"],
                  r_on["trace_dropped"], r_on["reconcile_ok"]]])
    rc = 0
    if out_on != out_off:
        print("[engine_real] FAIL: tracing on/off greedy outputs diverge")
        rc = 1
    if not r_on["reconcile_ok"]:
        print(f"[engine_real] FAIL: span timeline disagrees with "
              f"EngineStats: {r_on['reconcile_failed']}")
        rc = 1
    if r_on["trace_dropped"] > 0:
        print(f"[engine_real] FAIL: trace ring dropped "
              f"{r_on['trace_dropped']} events at smoke scale")
        rc = 1
    print(f"[engine_real] tracing gate: overhead={overhead:.2%}, outputs "
          f"{'identical' if out_on == out_off else 'DIVERGED'}, "
          f"reconcile_ok={r_on['reconcile_ok']}")
    return rc, results


def run_spec_section(n: int) -> Tuple[int, dict]:
    """Speculative decoding A/B on the decode-heavy fastdecode smoke, at
    LOW concurrency (n <= 3): speculation reclaims idle compute when decode
    is latency-bound — small batches whose step cost is dominated by
    per-iteration overhead rather than arithmetic.  That is the SpecOffload
    regime (and the perf model's job is to keep K priced so saturated
    batches don't speculate); at burst concurrency on this compute-bound
    CPU host the verify pass's extra pseudo-rows cost real FLOPs and the
    win disappears, so the gate pins the regime it claims.

    Three runs: spec off (reference), spec on with the default n-gram
    drafter, and spec on with an ORACLE drafter replaying the reference's
    own outputs (every draft verifies — the machinery's upper bound,
    independent of draft quality on a random-weights smoke model).

    GATES: greedy outputs bitwise identical to non-speculative decode for
    BOTH drafters (the batched verify pass rides the unchanged decode
    graph, so draft quality may only move throughput, never tokens); the
    oracle run must actually accept (accepted_tokens > 0, accepted-length
    histogram populated at >= 1) and must win on token throughput — each
    accepted token rides the verify pass instead of a full engine
    iteration, which is exactly the win speculation buys.
    """
    n = max(2, min(n, 3))
    r_off, out_off = run("fastdecode", n, pipeline=True, microbatch=True)
    r_ng, out_ng = run("fastdecode", n, spec=True)
    r_or, out_or = run("fastdecode", n, oracle_from=out_off)
    speedup = r_or["token_throughput"] / max(r_off["token_throughput"], 1e-9)
    if speedup <= 1.0:
        # wall-clock A/B on a shared host is noisy: re-measure both sides
        # once and keep each side's best run before declaring no win
        r_off2, _ = run("fastdecode", n, pipeline=True, microbatch=True)
        r_or2, out_or2 = run("fastdecode", n, oracle_from=out_off)
        if r_off2["token_throughput"] > r_off["token_throughput"]:
            r_off = r_off2
        if (out_or2 == out_off
                and r_or2["token_throughput"] > r_or["token_throughput"]):
            r_or = r_or2
        speedup = r_or["token_throughput"] / max(r_off["token_throughput"],
                                                 1e-9)
    r_or = dict(r_or)
    r_or["spec_oracle_speedup"] = round(speedup, 3)
    results = {"spec_off": r_off, "spec_ngram": r_ng, "spec_oracle": r_or}
    print("=== Speculative decoding A/B (fastdecode, smoke) ===")
    print_table(["run", "tok/s", "spec steps", "drafted", "accepted",
                 "hist"],
                [[k, r["token_throughput"], r["spec_steps"],
                  r["drafted_tokens"], r["accepted_tokens"],
                  r["accept_len_hist"]] for k, r in results.items()])
    rc = 0
    if out_ng != out_off:
        print("[engine_real] FAIL: n-gram spec greedy outputs diverge from "
              "non-speculative decode")
        rc = 1
    if out_or != out_off:
        print("[engine_real] FAIL: oracle spec greedy outputs diverge from "
              "non-speculative decode")
        rc = 1
    if r_or["accepted_tokens"] <= 0:
        print("[engine_real] FAIL: oracle drafter accepted 0 tokens (the "
              "verify chain never accepted)")
        rc = 1
    if not any(int(k) >= 1 and v > 0
               for k, v in r_or["accept_len_hist"].items()):
        print("[engine_real] FAIL: accepted-length histogram is empty at "
              ">= 1 on the oracle run")
        rc = 1
    if speedup <= 1.0:
        print(f"[engine_real] FAIL: no speculative throughput win "
              f"(oracle {r_or['token_throughput']} <= "
              f"off {r_off['token_throughput']} tok/s)")
        rc = 1
    print(f"[engine_real] spec gate: oracle speedup={speedup:.3f}x, "
          f"ngram accepted={r_ng['accepted_tokens']}/"
          f"{r_ng['drafted_tokens']}, outputs "
          f"{'identical' if out_ng == out_off == out_or else 'DIVERGED'}")
    results["spec_gates"] = {
        "bitwise_ok": out_ng == out_off and out_or == out_off,
        "oracle_speedup": round(speedup, 3),
    }
    return rc, results


def run_lockstep(policy: str, n: int, seed: int = 0, *, pipeline: bool = True,
                 prompt_len: int = 30, n_out: int = 24, device_pages: int = 11,
                 host_pages: int = 128):
    """Uniform-length lockstep decode under device-pool pressure: every row
    crosses a page boundary on the same iteration, so the scheduler must
    swap out several victims at once while survivors keep decoding on the
    device — a mixed decode-only plan (SHORT device lane, no prefill) whose
    surplus host rows are exactly the lane-borrowing shape.
    """
    cfg = get_smoke_config("qwen3-0.6b")
    model = get_model(cfg)
    import jax

    params = model.init(jax.random.key(seed))
    ecfg = EngineConfig(
        device_pool_pages=device_pages, host_pool_pages=host_pages,
        max_batch_tokens=1024, policy=policy, pipeline=pipeline, seed=seed,
    )
    eng = NeoEngine(cfg, ecfg, params=params)
    rng = np.random.default_rng(seed)
    prompts = [list(map(int, rng.integers(1, cfg.vocab_size, size=prompt_len)))
               for _ in range(n)]
    rids = [eng.submit(p, n_out) for p in prompts]
    t0 = time.perf_counter()
    eng.run_until_done(max_iters=2000)
    wall = time.perf_counter() - t0
    out = {
        "policy": policy,
        "pipeline": pipeline,
        "token_throughput": round(n * (prompt_len + n_out) / wall, 1),
        "iterations": eng.stats.iterations,
        "offloaded": eng.stats.offloaded_decodes,
        "borrowed_lane_steps": eng.stats.borrowed_lane_steps,
        "microbatched_steps": eng.stats.microbatched_steps,
        "lane_count_steps": {str(k): v
                             for k, v in sorted(eng.stats.lane_counts.items())},
        "bubble_fraction": round(eng.stats.bubble_fraction, 3),
        "overlap_s": round(eng.stats.pipeline_overlap_time, 3),
    }
    outputs = {i: list(eng.requests[rid].out_tokens)
               for i, rid in enumerate(rids)}
    eng.close()
    return out, outputs


def run_mixed_lane_section(n: int = 6) -> Tuple[int, dict]:
    """Mixed-plan lane borrowing: a decode-only plan with a SHORT device
    lane and >= 2 surplus host rows (swap-out burst victims) must execute
    micro-batched — borrowed host lanes overlapping the device dispatch —
    with bitwise-identical greedy outputs vs the serial reference.
    GATES: outputs identical AND borrowed_lane_steps > 0.
    """
    r_ser, out_ser = run_lockstep("neo", n, pipeline=False)
    r_pipe, out_pipe = run_lockstep("neo", n, pipeline=True)
    results = {"mixed_serial": r_ser, "mixed_pipelined": r_pipe}
    rows = [[k, r["iterations"], r["offloaded"], r["borrowed_lane_steps"],
             r["microbatched_steps"], r["lane_count_steps"],
             r["bubble_fraction"], r["token_throughput"]]
            for k, r in results.items()]
    print("=== Mixed-plan lane borrowing (neo lockstep, smoke) ===")
    print_table(["run", "iters", "offl dec", "borrowed", "mb steps",
                 "lanes", "bubble", "tok/s"], rows)
    rc = 0
    if out_pipe != out_ser:
        print("[engine_real] FAIL: lane-borrowing greedy outputs diverge "
              "from the serial path")
        rc = 1
    if r_pipe["borrowed_lane_steps"] == 0:
        print("[engine_real] FAIL: no borrowed-lane steps on a swap-burst "
              "trace (mixed short-device-lane plans must split batch-1)")
        rc = 1
    print(f"[engine_real] mixed-lane gate: borrowed_lane_steps="
          f"{r_pipe['borrowed_lane_steps']}, outputs "
          f"{'identical' if out_pipe == out_ser else 'DIVERGED'}")
    return rc, results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=24)
    ap.add_argument("--microbatch-only", action="store_true",
                    help="run only the micro-batch on/off gate (CI smoke)")
    ap.add_argument("--mixed-lane-only", action="store_true",
                    help="run only the mixed-plan lane-borrowing gate "
                         "(CI smoke)")
    ap.add_argument("--obs-only", action="store_true",
                    help="run only the tracing-overhead A/B gate (CI smoke)")
    ap.add_argument("--spec-only", action="store_true",
                    help="run only the speculative-decoding A/B gate "
                         "(CI smoke)")
    args = ap.parse_args(argv)

    def merge_save(new_results: dict) -> None:
        # merge into the existing figure JSON instead of clobbering the
        # full policy comparison (the CI / local gates update one section)
        import json
        import os

        from benchmarks.common import FIG_DIR
        merged = {}
        path = os.path.join(FIG_DIR, "engine_real.json")
        if os.path.exists(path):
            with open(path) as f:
                merged = json.load(f)
        merged.update(new_results)
        save_json("engine_real.json", merged)

    rows = []
    results = {}
    fastdecode_run = None
    if args.mixed_lane_only:
        rc, ml_results = run_mixed_lane_section()
        merge_save(ml_results)
        return rc
    if args.obs_only:
        rc, obs_results = run_obs_section(args.n)
        merge_save(obs_results)
        return rc
    if args.spec_only:
        rc, spec_results = run_spec_section(args.n)
        merge_save(spec_results)
        return rc
    if not args.microbatch_only:
        # neo runs twice: serial reference first, then pipelined (the
        # default) — the delta is the realized (not modelled) overlap win.
        # Serial runs first so the process-global op caches it warms don't
        # bias against it.
        for pol, pipe in (("gpu_only", True), ("neo", False), ("neo", True),
                          ("fastdecode", True)):
            r, outs = run(pol, args.n, pipeline=pipe)
            key = pol if pipe else pol + "_serial"
            results[key] = r
            if key == "fastdecode":
                fastdecode_run = (r, outs)
            rows.append([key, r["requests_done"], r["token_throughput"],
                         r["iterations"], r["offloaded"], r["device"],
                         r["swap_MB"], r["overlap_s"], r["bubble_fraction"]])
        print("=== Real engine (smoke qwen3-0.6b, OSC burst, this host) ===")
        print_table(["policy", "done", "tok/s", "iters", "offl dec",
                     "dev dec", "swap MB", "overlap s", "bubble"], rows)
    rc, mb_results = run_microbatch_section(args.n, on=fastdecode_run)
    if not args.microbatch_only:
        rc2, ml_results = run_mixed_lane_section()
        rc3, obs_results = run_obs_section(args.n, off=fastdecode_run)
        rc4, spec_results = run_spec_section(args.n)
        mb_results = {**mb_results, **ml_results, **obs_results,
                      **spec_results}
        rc = rc or rc2 or rc3 or rc4
    merge_save({**results, **mb_results})
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
