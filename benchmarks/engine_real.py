"""Real-engine benchmark (Fig. 10b spirit): GPU token throughput of the
actual NeoEngine on this host, smoke-scale models, feeding a whole trace at
once (the paper's "feed the Azure Code trace all at once" methodology).

Compares NEO scheduling vs the GPU-only baseline ON REAL EXECUTION — the
numbers are host-CPU wall times (not TPU projections), so the meaningful
output is the RELATIVE behaviour and the scheduler decision mix.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import print_table, save_json
from repro.config import EngineConfig
from repro.configs import get_smoke_config
from repro.core.engine import EngineStats, NeoEngine
from repro.core.transfer import TransferStats
from repro.models.api import get_model
from repro.serving.traces import get_trace


def run(policy: str, n: int, seed: int = 0, pipeline: bool = True):
    cfg = get_smoke_config("qwen3-0.6b")
    model = get_model(cfg)
    import jax

    params = model.init(jax.random.key(seed))
    ecfg = EngineConfig(
        device_pool_pages=24, host_pool_pages=128, max_batch_tokens=1024,
        policy=policy, pipeline=pipeline, seed=seed,
    )
    eng = NeoEngine(cfg, ecfg, params=params)
    rng = np.random.default_rng(seed)
    # Warmup: a burst big enough to trigger offload (device pool pressure),
    # exercising the prefill/decode/swap graph buckets so the timed section
    # measures steady-state serving throughput rather than XLA compile time
    # (the paper's figures report sustained serving).
    warm = get_trace("osc", 6, 1e9, seed + 1)
    for t in warm:
        t.prompt_len = 256
        t.output_len = 16
        t.materialise(rng, cfg.vocab_size)
        eng.submit(t.prompt, t.output_len)
    eng.run_until_done(max_iters=2000)
    eng.stats = EngineStats()
    if eng.pool is not None:
        eng.pool.swap_bytes = 0
    if eng.transfer is not None:
        eng.transfer.stats = TransferStats()

    trace = get_trace("osc", n, 1e9, seed)  # all at once
    total_tokens = 0
    rids = []
    for t in trace:
        t.prompt_len = min(t.prompt_len, 256)
        # decode-heavy outputs (the paper's code/conv traces decode hundreds
        # of tokens per request — decode is where the asymmetric overlap acts)
        t.output_len = min(t.output_len, 64)
        t.materialise(rng, cfg.vocab_size)
        rids.append(eng.submit(t.prompt, t.output_len))
        total_tokens += t.prompt_len + t.output_len
    t0 = time.perf_counter()
    eng.run_until_done(max_iters=5000)
    wall = time.perf_counter() - t0
    done = sum(1 for rid in rids if eng.requests[rid].state.name == "FINISHED")
    out = {
        "policy": policy,
        "pipeline": pipeline,
        "requests_done": done,
        "token_throughput": round(total_tokens / wall, 1),
        "wall_s": round(wall, 2),
        "iterations": eng.stats.iterations,
        "offloaded": eng.stats.offloaded_decodes,
        "device": eng.stats.device_decodes,
        "swap_MB": round(eng.pool.swap_bytes / 1e6, 1) if eng.pool else 0,
        "modes": dict(eng.stats.mode_counts),
        "host_busy_s": round(eng.stats.host_busy_time, 2),
        "device_busy_s": round(eng.stats.device_busy_time, 2),
        "overlap_s": round(eng.stats.pipeline_overlap_time, 3),
        "bubble_fraction": round(eng.stats.bubble_fraction, 3),
        "swap_hidden_MB": round(eng.stats.swap_hidden_bytes / 1e6, 3),
    }
    eng.close()
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=24)
    args = ap.parse_args(argv)
    rows = []
    results = {}
    # neo runs twice: serial reference first, then pipelined (the default) —
    # the delta is the realized (not modelled) overlap win.  Serial runs
    # first so the process-global op caches it warms don't bias against it.
    for pol, pipe in (("gpu_only", True), ("neo", False), ("neo", True),
                      ("fastdecode", True)):
        r = run(pol, args.n, pipeline=pipe)
        key = pol if pipe else pol + "_serial"
        results[key] = r
        rows.append([key, r["requests_done"], r["token_throughput"],
                     r["iterations"], r["offloaded"], r["device"],
                     r["swap_MB"], r["overlap_s"], r["bubble_fraction"]])
    print("=== Real engine (smoke qwen3-0.6b, OSC burst, this host) ===")
    print_table(["policy", "done", "tok/s", "iters", "offl dec", "dev dec",
                 "swap MB", "overlap s", "bubble"], rows)
    save_json("engine_real.json", results)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
