"""Benchmark aggregator: one section per paper table/figure plus the
dry-run roofline table.  ``python -m benchmarks.run [--quick]``."""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweeps (CI-sized)")
    ap.add_argument("--skip-real", action="store_true",
                    help="skip the real-engine benchmark (slowest section)")
    args = ap.parse_args(argv)
    q = ["--quick"] if args.quick else []
    t0 = time.time()

    from benchmarks import (engine_real, fig6_load_latency, fig8_fastdecode,
                            fig9_lengths, fig10a_cpu, kernels, prefix_cache,
                            roofline_table)

    print("#" * 70)
    print("# NEO-on-TPU benchmark suite (simulator figures use the real")
    print("# scheduler + calibrated hardware model; see DESIGN.md §7)")
    print("#" * 70)

    sections = [
        ("Fig. 6/7 load-latency", lambda: fig6_load_latency.main(q + ["--dist"])),
        ("Fig. 8 FastDecode+", lambda: fig8_fastdecode.main(q)),
        ("Fig. 9 length grid", lambda: fig9_lengths.main(q)),
        ("Fig. 10a host bandwidth", lambda: fig10a_cpu.main(q)),
        ("Kernels", lambda: kernels.main([])),
    ]
    if not args.skip_real:
        sections.append(("Real engine (Fig. 10b spirit)", lambda: engine_real.main([])))
        sections.append(("Prefix cache (multiturn)", lambda: prefix_cache.main(q)))
    sections.append(("Roofline table", lambda: roofline_table.main()))

    failures = []
    for name, fn in sections:
        print(f"\n{'=' * 70}\n== {name}\n{'=' * 70}")
        try:
            rc = fn()
            if rc:  # sections signal gate failures via nonzero return codes
                failures.append((name, f"exit {rc}"))
        except Exception as e:  # noqa: BLE001
            import traceback

            traceback.print_exc()
            failures.append((name, repr(e)))
    print(f"\n[benchmarks] done in {time.time() - t0:.0f}s; "
          f"{len(failures)} failures {failures if failures else ''}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
