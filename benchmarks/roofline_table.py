"""Aggregate dry-run artifacts into the EXPERIMENTS.md roofline table.

Reads ``experiments/dryrun/*.json`` (written by ``repro.launch.dryrun``) and
emits the §Dry-run and §Roofline markdown sections.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

HERE = os.path.dirname(os.path.abspath(__file__))
DRYRUN_DIR = os.path.join(HERE, "..", "experiments", "dryrun")

ARCH_ORDER = [
    "qwen3-0.6b", "qwen3-14b", "qwen3-32b", "yi-9b", "rwkv6-7b",
    "deepseek-moe-16b", "llama4-maverick-400b-a17b", "internvl2-1b",
    "seamless-m4t-medium", "zamba2-7b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str = "16x16") -> List[Dict]:
    rows = []
    for path in glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json")):
        with open(path) as f:
            rows.append(json.load(f))
    rows.sort(key=lambda r: (ARCH_ORDER.index(r["arch"]) if r["arch"] in ARCH_ORDER else 99,
                             SHAPE_ORDER.index(r["shape"])))
    return rows


def fmt_seconds(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def roofline_markdown(mesh: str = "16x16") -> str:
    rows = load(mesh)
    out = [
        f"| arch | shape | kind | t_compute | t_memory | t_collective | bound | "
        f"useful/HLO | roofline-frac | resident GB/chip |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        rf = r["roofline"]
        out.append(
            "| {arch} | {shape} | {kind} | {tc} | {tm} | {tx} | {b} | {ur:.3f} | "
            "{frac:.4f} | {res:.2f} |".format(
                arch=r["arch"], shape=r["shape"], kind=r["kind"],
                tc=fmt_seconds(rf["t_compute_s"]), tm=fmt_seconds(rf["t_memory_s"]),
                tx=fmt_seconds(rf["t_collective_s"]), b=rf["bottleneck"],
                ur=rf["useful_flops_ratio"], frac=rf["roofline_fraction"],
                res=r.get("resident_bytes_per_chip", 0) / 1e9,
            )
        )
    return "\n".join(out)


def dryrun_markdown(mesh: str = "16x16") -> str:
    rows = load(mesh)
    out = [
        "| arch | shape | mesh | compile s | resident GB/chip | temp GB/chip (cpu-sched) | "
        "wire GB | AR/AG/RS/A2A/CP counts |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        c = r["collectives"]
        counts = "/".join(str(c.get(f"{op}_count", 0)) for op in
                          ("all-reduce", "all-gather", "reduce-scatter",
                           "all-to-all", "collective-permute"))
        out.append(
            "| {arch} | {shape} | {mesh} | {cs:.1f} | {res:.2f} | {tmp:.2f} | {wire} | {cnt} |".format(
                arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                cs=r["compile_s"],
                res=r.get("resident_bytes_per_chip", 0) / 1e9,
                tmp=r["memory_analysis"].get("temp_size_in_bytes", 0) / 1e9,
                wire=c.get("wire_GB", 0.0), cnt=counts,
            )
        )
    return "\n".join(out)


def main() -> None:
    for mesh in ("16x16", "2x16x16"):
        rows = load(mesh)
        if not rows:
            continue
        print(f"\n## Dry-run ({mesh}, {len(rows)} cells)\n")
        print(dryrun_markdown(mesh))
        if mesh == "16x16":
            print("\n## Roofline (single-pod)\n")
            print(roofline_markdown(mesh))


if __name__ == "__main__":
    main()
