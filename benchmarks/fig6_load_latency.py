"""Fig. 6 — load–latency curves, NEO vs GPU-only, three hardware classes.

Also Fig. 7 (``--dist``): the per-token latency distribution at a fixed rate
in the A10G setting.

Paper claims validated here: NEO sustains higher load at equal latency —
+14.3% on H100-class, +6.4% on A10G (at 2 s), ~5.6× on T4 (at 1 s).
"""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import FIG6_SETTINGS, print_table, save_json
from repro.configs import get_config
from repro.serving.simulator import simulate
from repro.serving.traces import get_trace


def sustained_rate(curve, latency_budget_s: float) -> float:
    """Largest request rate whose mean per-token latency fits the budget."""
    best = 0.0
    for rate, m in curve:
        if m.per_token_latency() <= latency_budget_s:
            best = max(best, rate)
    return best


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=150, help="requests per point")
    ap.add_argument("--dist", action="store_true", help="Fig. 7 distribution")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)

    results = {}
    for label, hw, arch, trace_name, tp, rates in FIG6_SETTINGS:
        cfg = get_config(arch)
        if args.quick:
            rates = rates[::2]
        rows = []
        curves = {"neo": [], "gpu_only": []}
        for rate in rates:
            trace = get_trace(trace_name, args.n, rate, seed=0)
            row = [rate]
            for pol in ("neo", "gpu_only"):
                m = simulate(cfg, trace, hw=hw, policy=pol, tp=tp)
                curves[pol].append((rate, m))
                row += [round(m.per_token_latency() * 1e3, 1),
                        round(m.throughput, 1),
                        m.summary()["offload_frac"]]
            rows.append(row)
        print(f"\n=== Fig6: {label} ===")
        print_table(
            ["rate", "neo ptl ms", "neo tok/s", "neo offl",
             "gpu ptl ms", "gpu tok/s", "gpu offl"], rows)
        budget = 1.0 if "T4" in label else 2.0
        r_neo = sustained_rate(curves["neo"], budget)
        r_gpu = sustained_rate(curves["gpu_only"], budget)
        gain = (r_neo / r_gpu - 1) * 100 if r_gpu else float("inf")
        print(f"sustained load at {budget:.0f}s per-token budget: "
              f"NEO {r_neo}/s vs GPU-only {r_gpu}/s -> +{gain:.1f}%")
        results[label] = {
            "rows": rows, "budget_s": budget,
            "neo_rate": r_neo, "gpu_rate": r_gpu, "gain_pct": round(gain, 1),
        }

    if args.dist:
        label, hw, arch, trace_name, tp, _ = FIG6_SETTINGS[1]
        cfg = get_config(arch)
        trace = get_trace(trace_name, args.n, 1.6, seed=0)
        print(f"\n=== Fig7: latency distribution ({label} @1.6/s) ===")
        rows = []
        for pol in ("neo", "gpu_only"):
            m = simulate(cfg, trace, hw=hw, policy=pol, tp=tp)
            d = m.latency_distribution() * 1e3
            pct = {p: round(float(np.percentile(d, p)), 1) for p in (50, 75, 90, 95, 99)}
            rows.append([pol] + list(pct.values()))
            results[f"fig7_{pol}"] = pct
        print_table(["policy", "p50 ms", "p75", "p90", "p95", "p99"], rows)

    save_json("fig6_load_latency.json", results)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
