"""Fig. 8 — NEO vs FastDecode+ (full offload) vs GPU-only baseline.

(a) latency on the AC trace in the 2×H100 + 70B setting;
(b) relative throughput with input fixed at 2000 and output length swept —
    the paper shows FastDecode+ collapsing below baseline at long outputs
    while NEO never drops below 1× (its scheduler falls back to GPU-only).
"""

from __future__ import annotations

import argparse

from benchmarks.common import print_table, save_json
from repro.configs import get_config
from repro.serving.simulator import simulate
from repro.serving.traces import get_trace, synthetic_trace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=120)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    cfg = get_config("llama31-70b")
    hw, tp = "h100_sxm", 2
    results = {}

    # (a) latency under load
    print("=== Fig8a: 2xH100+70B, AC trace, latency ===")
    rows = []
    rates = (1.0, 2.0) if args.quick else (0.5, 1.0, 1.5, 2.0, 2.5)
    for rate in rates:
        trace = get_trace("ac", args.n, rate, seed=0)
        row = [rate]
        for pol in ("neo", "fastdecode", "gpu_only"):
            m = simulate(cfg, trace, hw=hw, policy=pol, tp=tp)
            row.append(round(m.per_token_latency() * 1e3, 1))
        rows.append(row)
    print_table(["rate", "neo ptl ms", "fastdecode ptl ms", "gpu_only ptl ms"], rows)
    results["fig8a"] = rows

    # (b) relative throughput vs output length (input fixed at 2000)
    print("\n=== Fig8b: throughput relative to GPU-only, input=2000 ===")
    rows = []
    out_lens = (50, 200, 800) if args.quick else (25, 50, 100, 200, 400, 800)
    for out_len in out_lens:
        trace = synthetic_trace(args.n, 10.0, 2000, out_len, seed=0)
        base = simulate(cfg, trace, hw=hw, policy="gpu_only", tp=tp).throughput
        row = [out_len]
        for pol in ("neo", "fastdecode"):
            thr = simulate(cfg, trace, hw=hw, policy=pol, tp=tp).throughput
            row.append(round(thr / max(base, 1e-9), 3))
        rows.append(row)
    print_table(["output_len", "neo rel thr", "fastdecode rel thr"], rows)
    results["fig8b"] = rows
    save_json("fig8_fastdecode.json", results)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
